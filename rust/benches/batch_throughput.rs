//! Bench: batched multi-tenant spMTTKRP throughput on one shared SmPool.
//!
//!     cargo bench --bench batch_throughput
//!     SPMTTKRP_BENCH_SCALE=0.02 SPMTTKRP_BENCH_REPS=3 cargo bench ...
//!
//! The paper's tensors are *small*, so production traffic is many tensors
//! in flight, not one big one. This bench measures what the batch layer
//! buys: N tenants' per-mode partitions packed into one longest-first
//! queue (`Session::mttkrp_batch`) versus the sequential baseline (each
//! tenant's mode alone on the device, barrier between tenants). Both
//! numbers come from the same measured per-partition costs, so the ratio
//! isolates the scheduling win — idle-SM backfill — from machine noise.
//! See DESIGN.md §4 row B-T.

use spmttkrp::bench_support::report::{BenchCase, BenchReport};
use spmttkrp::bench_support::{
    batch_workload, bench_reps, bench_scale, print_table, time_sim_batch,
};
use spmttkrp::util::geomean;

fn main() {
    let rank = 16;
    let kappa = 82;
    let reps = bench_reps();
    let scale = bench_scale();
    println!("batch throughput bench: rank {rank}, κ {kappa}, reps {reps}, scale {scale}");
    let mut rows = Vec::new();
    let mut wins = Vec::new();
    let mut report = BenchReport::new("batch_throughput");
    for n_tenants in [1usize, 2, 4, 8] {
        let w = batch_workload(n_tenants, rank, kappa, scale);
        let reqs = w.all_mode_requests();
        let (packed, sequential) = time_sim_batch(reps, &w.session, &reqs);
        let win = sequential.median / packed.median.max(1e-9);
        report.push(
            BenchCase::from_summary(format!("tenants{n_tenants}/packed"), &packed)
                .sim(packed.median)
                .extra("requests", reqs.len() as f64)
                .extra("win", win),
        );
        report.push(
            BenchCase::from_summary(format!("tenants{n_tenants}/sequential"), &sequential)
                .sim(sequential.median),
        );
        if n_tenants > 1 {
            wins.push(win);
        }
        rows.push(vec![
            n_tenants.to_string(),
            reqs.len().to_string(),
            format!("{:.3}±{:.3}", sequential.median * 1e3, sequential.stddev * 1e3),
            format!("{:.3}±{:.3}", packed.median * 1e3, packed.stddev * 1e3),
            format!("{:.2}x", win),
        ]);
    }
    print_table(
        "Batched multi-tenant spMTTKRP — modeled κ-SM time in ms, sequential barrier vs packed",
        &["tenants", "requests", "sequential", "packed", "win"],
        &rows,
    );
    println!(
        "\ngeomean packing win (≥2 tenants): {:.2}x on κ = {kappa} simulated SMs \
         (longest-first cross-tenant backfill)",
        geomean(&wins)
    );
    let path = report.write().expect("write BENCH_batch_throughput.json");
    println!("bench json: {}", path.display());
}
