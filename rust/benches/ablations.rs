//! Ablation benches for the design choices DESIGN.md calls out:
//!
//!   seg        in-kernel segmented reduction on/off (the "no intermediate
//!              values to global memory" mechanism)
//!   assign     cyclic (paper) vs greedy-LPT vertex dealing in Scheme 1
//!   kappa      SM-count sweep (κ = 8..256): occupancy vs partition overhead
//!   blockp     native block size P sweep (kernel dispatch granularity)
//!   runtime    native vs PJRT backend on identical work (dispatch overhead
//!              of the AOT/XLA hot path)
//!
//!     cargo bench --bench ablations [-- seg|assign|kappa|blockp|runtime]

use std::sync::Arc;

use spmttkrp::bench_support::{bench_reps, print_table, time, Workload};
use spmttkrp::prelude::*;
use spmttkrp::tensor::synth::DatasetProfile;
use spmttkrp::util::human_bytes;

fn builder(rank: usize) -> ExecutorBuilder {
    ExecutorBuilder::new().sm_count(82).rank(rank)
}

fn ablate_seg(reps: usize, rank: usize, pool: &Arc<SmPool>) {
    let mut rows = Vec::new();
    for w in Workload::all(rank) {
        let mk = |seg: bool| {
            builder(rank)
                .seg_kernel(seg)
                .pool(Arc::clone(pool))
                .build_engine(&w.tensor)
                .unwrap()
        };
        let (on, off) = (mk(true), mk(false));
        let t_on = time(reps, || {
            std::hint::black_box(on.execute_all_modes(&w.factors).unwrap());
        });
        let t_off = time(reps, || {
            std::hint::black_box(off.execute_all_modes(&w.factors).unwrap());
        });
        let (_, rep_on) = on.execute_all_modes(&w.factors).unwrap();
        let (_, rep_off) = off.execute_all_modes(&w.factors).unwrap();
        rows.push(vec![
            w.profile.name.to_string(),
            format!("{:.2}", t_on.median * 1e3),
            format!("{:.2}", t_off.median * 1e3),
            format!("{:.2}x", t_off.median / t_on.median),
            human_bytes(rep_on.total_traffic().intermediate_bytes),
            human_bytes(rep_off.total_traffic().intermediate_bytes),
        ]);
    }
    print_table(
        "ablation: in-kernel segmented reduction (ms median)",
        &["tensor", "seg-on", "seg-off", "speedup", "spill-on", "spill-off"],
        &rows,
    );
}

fn ablate_assign(reps: usize, rank: usize, pool: &Arc<SmPool>) {
    let mut rows = Vec::new();
    for w in Workload::all(rank) {
        let mut medians = Vec::new();
        let mut imb = Vec::new();
        for assign in [VertexAssign::Cyclic, VertexAssign::Greedy] {
            let e = builder(rank)
                .vertex_assign(assign)
                .pool(Arc::clone(pool))
                .build_engine(&w.tensor)
                .unwrap();
            let s = time(reps, || {
                std::hint::black_box(e.execute_all_modes(&w.factors).unwrap());
            });
            medians.push(s.median);
            let worst = e
                .format
                .copies
                .iter()
                .map(|c| {
                    spmttkrp::partition::stats::evaluate(&c.partitioning, 0)
                        .imbalance
                        .factor
                })
                .fold(0.0f64, f64::max);
            imb.push(worst);
        }
        rows.push(vec![
            w.profile.name.to_string(),
            format!("{:.2}", medians[0] * 1e3),
            format!("{:.2}", medians[1] * 1e3),
            format!("{:.3}", imb[0]),
            format!("{:.3}", imb[1]),
        ]);
    }
    print_table(
        "ablation: cyclic (paper) vs greedy-LPT vertex dealing",
        &["tensor", "cyclic-ms", "greedy-ms", "imb-cyclic", "imb-greedy"],
        &rows,
    );
}

fn ablate_kappa(reps: usize, rank: usize, pool: &Arc<SmPool>) {
    let w = Workload::prepare(
        DatasetProfile::uber(),
        spmttkrp::bench_support::bench_scale(),
        rank,
        7,
    );
    let mut rows = Vec::new();
    for kappa in [8usize, 16, 32, 82, 128, 256] {
        let e = builder(rank)
            .sm_count(kappa)
            .pool(Arc::clone(pool))
            .build_engine(&w.tensor)
            .unwrap();
        let s = time(reps, || {
            std::hint::black_box(e.execute_all_modes(&w.factors).unwrap());
        });
        let (_, rep) = e.execute_all_modes(&w.factors).unwrap();
        rows.push(vec![
            format!("{kappa}"),
            format!("{:.2}", s.median * 1e3),
            format!("{}", rep.total_traffic().global_atomics),
        ]);
    }
    print_table(
        "ablation: κ sweep (uber profile, total ms)",
        &["kappa", "ms", "global-atomics"],
        &rows,
    );
}

fn ablate_blockp(reps: usize, rank: usize, pool: &Arc<SmPool>) {
    let w = Workload::prepare(
        DatasetProfile::uber(),
        spmttkrp::bench_support::bench_scale(),
        rank,
        7,
    );
    let mut rows = Vec::new();
    for p in [32usize, 64, 128, 256, 512, 1024] {
        let e = builder(rank)
            .block_p(p)
            .pool(Arc::clone(pool))
            .build_engine(&w.tensor)
            .unwrap();
        let s = time(reps, || {
            std::hint::black_box(e.execute_all_modes(&w.factors).unwrap());
        });
        rows.push(vec![format!("{p}"), format!("{:.2}", s.median * 1e3)]);
    }
    print_table(
        "ablation: block size P sweep (uber, native backend)",
        &["P", "ms"],
        &rows,
    );
}

fn ablate_runtime(reps: usize, rank: usize, pool: &Arc<SmPool>) {
    let w = Workload::prepare(DatasetProfile::uber(), 0.01, rank, 7);
    let native = builder(rank)
        .pool(Arc::clone(pool))
        .build_engine(&w.tensor)
        .unwrap();
    let t_native = time(reps, || {
        std::hint::black_box(native.execute_all_modes(&w.factors).unwrap());
    });
    let mut rows = vec![vec![
        "native".to_string(),
        format!("{:.2}", t_native.median * 1e3),
        "1.00x".to_string(),
    ]];
    match builder(rank).backend(BackendKind::Pjrt).build_engine(&w.tensor) {
        Ok(pjrt) => {
            pjrt.mttkrp_all_modes(&w.factors).unwrap(); // compile outside timing
            let t_pjrt = time(reps, || {
                std::hint::black_box(pjrt.execute_all_modes(&w.factors).unwrap());
            });
            rows.push(vec![
                "pjrt".to_string(),
                format!("{:.2}", t_pjrt.median * 1e3),
                format!("{:.2}x", t_pjrt.median / t_native.median),
            ]);
        }
        Err(e) => println!("(pjrt unavailable: {e} — run `make artifacts`)"),
    }
    print_table(
        "ablation: backend dispatch (uber @ 1% scale, total ms)",
        &["backend", "ms", "vs-native"],
        &rows,
    );
}

fn main() {
    let rank = 32;
    let reps = bench_reps();
    let which: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let all = which.is_empty();
    let has = |k: &str| all || which.iter().any(|w| w == k);
    println!(
        "ablations: rank {rank}, reps {reps}, scale {}",
        spmttkrp::bench_support::bench_scale()
    );
    // one persistent SM pool serves every engine in every ablation
    let pool = Arc::new(SmPool::with_default_threads());
    if has("seg") {
        ablate_seg(reps, rank, &pool);
    }
    if has("assign") {
        ablate_assign(reps, rank, &pool);
    }
    if has("kappa") {
        ablate_kappa(reps, rank, &pool);
    }
    if has("blockp") {
        ablate_blockp(reps, rank, &pool);
    }
    if has("runtime") {
        ablate_runtime(reps, rank, &pool);
    }
}
