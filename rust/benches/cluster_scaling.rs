//! Bench: simulated multi-GPU scaling of the batched spMTTKRP dispatch.
//!
//!     cargo bench --bench cluster_scaling
//!     SPMTTKRP_BENCH_SCALE=0.02 SPMTTKRP_BENCH_REPS=3 cargo bench ...
//!
//! The same multi-tenant workload is dispatched on a `DeviceCluster` of
//! 1, 2 and 4 simulated GPUs (`SessionBuilder::devices`). Reported per
//! device count:
//!
//!   * the modeled *cluster makespan* — the slowest device's hierarchical
//!     LPT makespan (level 1 shards tenants' partitions across devices by
//!     nnz, level 2 is the per-pool longest-first schedule), which is the
//!     scaling curve;
//!   * the modeled inter-device reduction bytes (`ClusterCounters`):
//!     every non-primary device's staged row-partials fold into device 0,
//!     so merged bytes *grow* with N while makespan shrinks — the
//!     communication/parallelism trade the paper's single-GPU design
//!     sidesteps and a multi-GPU deployment must price;
//!   * the level-1 shard imbalance (max/mean of device nnz loads).
//!
//! Before timing, the outputs at every device count are checked bitwise
//! against N = 1 — the D1 invariant the property suite
//! (`tests/cluster_exec.rs`) pins, re-asserted here on the bench
//! workload itself. See DESIGN.md §6 invariant D1.

use spmttkrp::bench_support::report::{BenchCase, BenchReport};
use spmttkrp::bench_support::{batch_workload_devices, bench_reps, bench_scale, print_table};
use spmttkrp::util::human_bytes;

fn main() {
    let rank = 16;
    let kappa = 82;
    let n_tenants = 6;
    let reps = bench_reps();
    let scale = bench_scale();
    println!(
        "cluster scaling bench: {n_tenants} tenants, rank {rank}, κ {kappa}, \
         reps {reps}, scale {scale}"
    );

    // D1 reference: the single-device outputs at this exact workload.
    let reference = {
        let w = batch_workload_devices(n_tenants, rank, kappa, scale, 1);
        let reqs = w.all_mode_requests();
        w.session.mttkrp_batch(&reqs).expect("reference dispatch").outputs
    };

    let mut rows = Vec::new();
    let mut report = BenchReport::new("cluster_scaling");
    for devices in [1usize, 2, 4] {
        let w = batch_workload_devices(n_tenants, rank, kappa, scale, devices);
        let reqs = w.all_mode_requests();

        // bitwise D1 check on the bench workload before anything is timed
        let check = w.session.mttkrp_batch(&reqs).expect("warmup dispatch");
        assert_eq!(check.outputs.len(), reference.len());
        for (r, (got, want)) in check.outputs.iter().zip(&reference).enumerate() {
            assert_eq!(got.len(), want.len(), "req {r}: output length");
            for (i, (a, b)) in got.iter().zip(want).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "req {r} [{i}]: devices={devices} diverged from devices=1 (D1)"
                );
            }
        }

        // timed reps: modeled cluster makespan (slowest device's LPT
        // schedule) de-noised with a median across reps
        let mut makespans = Vec::with_capacity(reps);
        let mut last = None;
        for _ in 0..reps {
            let b = w.session.mttkrp_batch(&reqs).expect("bench dispatch");
            let c = b.dispatch.cluster.expect("clustered session reports counters");
            makespans.push(c.cluster_makespan().as_secs_f64());
            last = Some(c);
        }
        let c = last.unwrap();
        let summary = spmttkrp::util::stats::Summary::of(&makespans);

        report.push(
            BenchCase::from_summary(format!("devices{devices}"), &summary)
                .sim(summary.median)
                .extra("devices", devices as f64)
                .extra("requests", reqs.len() as f64)
                .extra("bytes_staged", c.bytes_staged.iter().sum::<u64>() as f64)
                .extra("bytes_merged", c.bytes_merged as f64)
                .extra("shard_imbalance", c.imbalance.factor),
        );
        rows.push(vec![
            devices.to_string(),
            reqs.len().to_string(),
            format!("{:.3}±{:.3}", summary.median * 1e3, summary.stddev * 1e3),
            human_bytes(c.bytes_staged.iter().sum::<u64>()),
            human_bytes(c.bytes_merged),
            format!("{:.3}", c.imbalance.factor),
        ]);
    }
    print_table(
        "Cluster scaling — modeled cluster makespan in ms (hierarchical LPT, D1-checked)",
        &["devices", "requests", "makespan", "staged", "merged", "imbalance"],
        &rows,
    );
    let path = report.write().expect("write BENCH_cluster_scaling.json");
    println!("bench json: {}", path.display());
}
