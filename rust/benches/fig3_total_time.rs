//! Bench: Fig. 3 — total spMTTKRP execution time (all modes), ours vs the
//! three baselines on every Table III profile.
//!
//!     cargo bench --bench fig3_total_time
//!     SPMTTKRP_BENCH_SCALE=0.02 SPMTTKRP_BENCH_REPS=3 cargo bench ...
//!
//! Prints median ± stddev per executor per dataset, the speedup matrix,
//! the modeled memory-traffic comparison, and geomean rows matching the
//! paper's abstract (2.4x / 8.9x / 7.9x on the authors' GPU testbed; on
//! this simulated substrate the *ordering and direction* are the
//! reproduction target — see DESIGN.md §4 row F-3).

use spmttkrp::baselines::MttkrpExecutor;
use spmttkrp::bench_support::report::{BenchCase, BenchReport};
use spmttkrp::bench_support::{all_executors, bench_reps, print_table, time_sim, Workload};
use spmttkrp::util::{geomean, human_bytes};

const EXEC_NAMES: [&str; 4] = ["ours", "blco", "mm-csf", "parti"];

fn main() {
    let rank = 32;
    let reps = bench_reps();
    let workloads = Workload::all(rank);
    println!(
        "fig3 bench: rank {rank}, reps {reps}, scale {}",
        spmttkrp::bench_support::bench_scale()
    );
    let mut rows = Vec::new();
    let mut speedups: Vec<Vec<f64>> = vec![Vec::new(); 3];
    let mut traffic_ratio = Vec::new();
    let mut report = BenchReport::new("fig3_total_time");
    for w in &workloads {
        let execs = all_executors(&w.tensor, rank);
        let mut medians = Vec::new();
        let mut stddevs = Vec::new();
        let mut traffic = Vec::new();
        for (i, ex) in execs.iter().enumerate() {
            let s = time_sim(reps, ex.as_ref(), &w.factors);
            let (_, rep) = ex.execute_all_modes(&w.factors).unwrap();
            let t = rep.total_traffic();
            report.push(
                BenchCase::from_summary(format!("{}/{}", w.profile.name, EXEC_NAMES[i]), &s)
                    .sim(s.median)
                    .traffic(t),
            );
            medians.push(s.median);
            stddevs.push(s.stddev);
            traffic.push(t);
        }
        for b in 0..3 {
            speedups[b].push(medians[b + 1] / medians[0]);
        }
        traffic_ratio.push(
            traffic[3].total_bytes() as f64 / traffic[0].total_bytes() as f64,
        );
        rows.push(vec![
            w.profile.name.to_string(),
            format!("{:.2}±{:.2}", medians[0] * 1e3, stddevs[0] * 1e3),
            format!("{:.2}±{:.2}", medians[1] * 1e3, stddevs[1] * 1e3),
            format!("{:.2}±{:.2}", medians[2] * 1e3, stddevs[2] * 1e3),
            format!("{:.2}±{:.2}", medians[3] * 1e3, stddevs[3] * 1e3),
            format!("{:.2}x", medians[1] / medians[0]),
            format!("{:.2}x", medians[2] / medians[0]),
            format!("{:.2}x", medians[3] / medians[0]),
            human_bytes(traffic[0].total_bytes()),
            format!("{}", traffic[0].global_atomics),
            format!("{}", traffic[3].global_atomics),
        ]);
    }
    print_table(
        "Fig. 3 — simulated κ-SM total execution time in ms (median±σ); \
         speedups = baseline/ours",
        &[
            "tensor", "ours", "blco", "mm-csf", "parti", "vs-blco", "vs-mmcsf",
            "vs-parti", "traffic", "atomics-ours", "atomics-parti",
        ],
        &rows,
    );
    println!(
        "\ngeomean speedups: vs BLCO {:.2}x (paper 2.4x) | vs MM-CSF {:.2}x \
         (paper 8.9x) | vs ParTI {:.2}x (paper 7.9x)",
        geomean(&speedups[0]),
        geomean(&speedups[1]),
        geomean(&speedups[2]),
    );
    println!(
        "modeled traffic: ParTI moves {:.2}x the bytes we do (geomean)",
        geomean(&traffic_ratio)
    );
    let path = report.write().expect("write BENCH_fig3_total_time.json");
    println!("bench json: {}", path.display());
}
